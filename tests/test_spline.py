"""Property tests for the natural cubic spline (paper appendix)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, never error
from hypothesis import given, settings, strategies as st

from repro.core.spline import CubicSpline, fit_natural_cubic, max_of_spline


@st.composite
def knot_data(draw, min_pts=3, max_pts=12):
    n = draw(st.integers(min_pts, max_pts))
    xs = draw(st.lists(st.floats(0.1, 1000, allow_nan=False),
                       min_size=n, max_size=n, unique=True))
    xs = sorted(xs)
    # ensure separation so the tridiagonal system is well conditioned
    xs = [x + i * 1e-3 for i, x in enumerate(xs)]
    ys = draw(st.lists(st.floats(-100, 100, allow_nan=False),
                       min_size=n, max_size=n))
    return np.array(xs), np.array(ys)


@given(knot_data())
@settings(max_examples=60, deadline=None)
def test_interpolates_knots(data):
    xs, ys = data
    sp = fit_natural_cubic(xs, ys)
    np.testing.assert_allclose(sp(xs), ys, rtol=1e-8, atol=1e-7)


@given(knot_data(min_pts=4))
@settings(max_examples=60, deadline=None)
def test_c1_c2_continuity_at_interior_knots(data):
    """Algebraic continuity of S' and S'' at each interior knot (exact
    left-segment polynomial evaluated at the knot vs right coefficients)."""
    xs, ys = data
    sp = fit_natural_cubic(xs, ys)
    h = np.diff(sp.x)
    for i in range(len(xs) - 2):
        # derivative of segment i at its right end vs segment i+1 at left
        d_left = sp.b[i] + 2 * sp.c[i] * h[i] + 3 * sp.d[i] * h[i] ** 2
        d_right = sp.b[i + 1]
        scale = max(abs(d_left), abs(d_right), 1.0)
        assert abs(d_left - d_right) / scale < 1e-6
        # second derivative
        s_left = 2 * sp.c[i] + 6 * sp.d[i] * h[i]
        s_right = 2 * sp.c[i + 1]
        scale2 = max(abs(s_left), abs(s_right), 1.0)
        assert abs(s_left - s_right) / scale2 < 1e-6


@given(st.integers(3, 10), st.floats(-5, 5), st.floats(-5, 5))
@settings(max_examples=40, deadline=None)
def test_linear_data_reproduced(n, a, b):
    xs = np.arange(1, n + 1, dtype=float)
    ys = a * xs + b
    sp = fit_natural_cubic(xs, ys)
    grid = np.linspace(1, n, 50)
    np.testing.assert_allclose(sp(grid), a * grid + b, rtol=1e-6, atol=1e-6)


def test_natural_boundary_second_derivative_zero():
    xs = np.array([1.0, 2.0, 4.0, 7.0, 11.0])
    ys = np.array([1.0, 3.0, 2.0, 5.0, 4.0])
    sp = fit_natural_cubic(xs, ys)
    # c coefficients are S''/2 at knots; natural BC => c[0] == c[-1] == 0
    assert abs(sp.c[0]) < 1e-12
    assert abs(sp.c[-1]) < 1e-12


def test_max_of_spline_finds_peak():
    xs = np.array([1.0, 2.0, 4.0, 8.0, 16.0, 32.0])
    ys = -(xs - 8.0) ** 2 + 100.0
    sp = fit_natural_cubic(xs, ys)
    argmax, mx = max_of_spline(sp, 1.0, 32.0)
    assert 6.0 < argmax < 10.0
    assert mx >= 99.0


def test_spline_error_small_on_saturating_curve():
    """Fig. 7 of the paper: spline vs dense ground truth on a GPU-like
    saturating speed curve — interpolation error should be ~0."""
    b = np.arange(1.0, 65.0)
    speed = 100.0 * b / (b + 4.0)
    knots = np.array([1, 2, 4, 8, 16, 24, 32, 48, 64], dtype=float)
    sp = fit_natural_cubic(knots, 100.0 * knots / (knots + 4.0))
    rel_err = np.abs(sp(b) - speed) / speed
    assert rel_err.max() < 0.02
