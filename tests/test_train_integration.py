"""End-to-end integration: Poplar plan -> hetero loader -> masked train
steps; loss decreases; hetero-masked gradients equal dense gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sharding import MeshRules
from repro.core.zero import make_train_step, register_axes
from repro.launch.mesh import make_debug_mesh
from repro.models import model as mm
from repro.optim.adamw import adamw_init


def test_loss_decreases_small_llama():
    cfg = get_config("llama-0.5b", reduced=True)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    rules = MeshRules(make_debug_mesh(1), zero_stage=0)
    register_axes(rules, axes)
    step = jax.jit(make_train_step(cfg, rules, lr=3e-3))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    # tiny memorizable dataset
    toks = jnp.asarray(rng.integers(3, 64, (4, 33)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "loss_mask": jnp.ones((4, 32), jnp.float32)}
    losses = []
    for _ in range(30):
        params, opt, met = step(params, opt, batch)
        losses.append(float(met["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]


def test_masked_padding_rows_do_not_change_gradients():
    """The SPMD hetero layout's correctness hinge: a batch padded with
    masked rows must produce identical loss/gradients to the dense batch."""
    cfg = get_config("llama-0.5b", reduced=True)
    params, _ = mm.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (4, 17)), jnp.int32)
    dense = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "loss_mask": jnp.ones((4, 16), jnp.float32)}
    # pad with 4 garbage rows, masked out
    junk = jnp.asarray(rng.integers(3, cfg.vocab_size, (4, 16)), jnp.int32)
    padded = {
        "tokens": jnp.concatenate([dense["tokens"], junk]),
        "labels": jnp.concatenate([dense["labels"], junk]),
        "loss_mask": jnp.concatenate(
            [dense["loss_mask"], jnp.zeros((4, 16), jnp.float32)]),
    }

    def loss(p, b):
        return mm.loss_fn(p, cfg, b)[0]

    l1, g1 = jax.value_and_grad(loss)(params, dense)
    l2, g2 = jax.value_and_grad(loss)(params, padded)
    assert abs(float(l1) - float(l2)) < 1e-4
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=3e-3)


def test_grad_accumulation_parity_masked_rows():
    """Poplar's hetero layout pads uneven per-device shares with masked
    rows inside the accumulation micro-batches: accum_steps>1 with masked
    padding must reproduce the single step on the concatenated *dense*
    batch (loss and updated params) — the token-weighted micro loop must
    not let padded rows shift the normalization."""
    cfg = get_config("llama-0.5b", reduced=True)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    rules = MeshRules(make_debug_mesh(1), zero_stage=0)
    register_axes(rules, axes)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (6, 17)), jnp.int32)
    dense = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "loss_mask": jnp.ones((6, 16), jnp.float32)}
    # micro-batches of 4 rows: [4 real] + [2 real, 2 masked junk]
    junk = jnp.asarray(rng.integers(3, cfg.vocab_size, (2, 16)), jnp.int32)

    def stack_with_padding(k, pad):
        v = dense[k]
        mb1 = v[:4]
        mb2 = jnp.concatenate([v[4:], pad])
        return jnp.stack([mb1, mb2])

    stacked = {
        "tokens": stack_with_padding("tokens", junk),
        "labels": stack_with_padding("labels", junk),
        "loss_mask": stack_with_padding("loss_mask",
                                        jnp.zeros((2, 16), jnp.float32)),
    }
    opt = adamw_init(params)
    one = jax.jit(make_train_step(cfg, rules, lr=1e-3))
    acc = jax.jit(make_train_step(cfg, rules, lr=1e-3, accum_steps=2))
    p1, _, m1 = one(params, opt, dense)
    p2, _, m2 = acc(params, opt, stacked)
    assert float(m1["tokens"]) == float(m2["tokens"]) == 96.0
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-3)


def test_grad_accumulation_matches_single_batch():
    """gas>1 (Poplar's gmbs/lbs loop) must match the one-shot gradient."""
    cfg = get_config("llama-0.5b", reduced=True)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    rules = MeshRules(make_debug_mesh(1), zero_stage=0)
    register_axes(rules, axes)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (4, 17)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "loss_mask": jnp.ones((4, 16), jnp.float32)}
    stacked = jax.tree.map(lambda x: x.reshape((2, 2) + x.shape[1:]), batch)
    opt = adamw_init(params)
    one = jax.jit(make_train_step(cfg, rules, lr=1e-3))
    acc = jax.jit(make_train_step(cfg, rules, lr=1e-3, accum_steps=2))
    p1, _, m1 = one(params, opt, batch)
    p2, _, m2 = acc(params, opt, stacked)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-3)
